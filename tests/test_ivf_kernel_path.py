"""Kernel-backed IVF probe path + candidate-sparse fusion.

Parity: the fused-Pallas probe path must agree with the legacy fp32
gather-dequant einsum path (both score the same quantized rows, so scores
match to fp rounding and ids match wherever scores are distinct), and both
must hit brute-force recall at full probe. Fusion: the sparse candidate
formulation must reproduce dense fusion exactly, and its jaxpr must contain
no intermediate sized by n_nodes (the memory claim, checked structurally).
"""
import functools

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import delta as delta_mod
from repro.core import index as index_mod
from repro.core import ivf as ivf_mod
from repro.core.fusion import FusionWeights, fuse_topk, fuse_topk_sparse
from repro.core.quantization import quantize
from repro.kernels.ivf_topk.ops import scan_topk_quantized_batched
from repro.kernels.ivf_topk.ref import scan_topk_ref_batched, topk_from_chunks


def _corpus(rng, n, d):
    v = rng.normal(size=(n, d)).astype(np.float32)
    v /= np.linalg.norm(v, axis=1, keepdims=True)
    return v


class TestKernelProbeParity:
    @pytest.mark.parametrize("n,d,k_parts,n_probe", [(1500, 48, 12, 3),
                                                     (3000, 96, 16, 16)])
    def test_kernel_matches_einsum(self, n, d, k_parts, n_probe, rng):
        v = _corpus(rng, n, d)
        idx, _ = ivf_mod.build(jax.random.PRNGKey(0), jnp.asarray(v),
                               jnp.arange(n), n_partitions=k_parts, bits=8)
        q = jnp.asarray(v[:24] + 0.02 * rng.normal(size=(24, d)).astype(np.float32))
        se, ie = ivf_mod.search(idx, q, n_probe=n_probe, k=10, impl="einsum")
        sk, ik = ivf_mod.search(idx, q, n_probe=n_probe, k=10, impl="kernel")
        np.testing.assert_allclose(np.asarray(sk), np.asarray(se),
                                   rtol=2e-5, atol=2e-5)
        assert np.mean(np.asarray(ik) == np.asarray(ie)) > 0.99

    def test_full_probe_matches_brute_force_recall(self, rng):
        n, d = 1500, 48
        v = _corpus(rng, n, d)
        idx, over = ivf_mod.build(jax.random.PRNGKey(0), jnp.asarray(v),
                                  jnp.arange(n), n_partitions=12, bits=8)
        q = jnp.asarray(v[:32] + 0.02 * rng.normal(size=(32, d)).astype(np.float32))
        bv, bi = ivf_mod.brute_force(jnp.asarray(v), ~over, jnp.arange(n), q, k=10)
        _, ik = ivf_mod.search(idx, q, n_probe=12, k=10, impl="kernel")
        hits = np.mean([len(set(map(int, a)) & set(map(int, b))) / 10
                        for a, b in zip(np.asarray(ik), np.asarray(bi))])
        assert hits > 0.9   # limited only by int8 quantization

    def test_empty_slots_and_overflow_k(self, rng):
        """Mostly-empty slab: no empty slot ever surfaces, tail pads -1."""
        n, d = 50, 32
        v = _corpus(rng, n, d)
        idx, over = ivf_mod.build(jax.random.PRNGKey(1), jnp.asarray(v),
                                  jnp.arange(n), n_partitions=8, bits=8)
        stored = int(np.sum(~np.asarray(over)))
        q = jnp.asarray(v[:4])
        sk, ik = ivf_mod.search(idx, q, n_probe=8, k=60, impl="kernel")
        ik, sk = np.asarray(ik), np.asarray(sk)
        for row_i, row_s in zip(ik, sk):
            live = row_i[row_i >= 0]
            assert len(live) == stored
            assert len(set(live.tolist())) == len(live)     # no dupes
            assert np.all(np.isneginf(row_s[row_i < 0]))    # dead ⇒ -inf

    def test_batched_kernel_matches_ref(self, rng):
        qn, m, d = 6, 512, 64
        v = rng.normal(size=(qn * m, d)).astype(np.float32)
        qv = quantize(jnp.asarray(v), 8)
        data = qv.data.reshape(qn, m, d)
        vmin = qv.vmin[:, 0].reshape(qn, m)
        scale = qv.scale[:, 0].reshape(qn, m)
        queries = jnp.asarray(rng.normal(size=(qn, d)).astype(np.float32))
        cm, ca = scan_topk_ref_batched(queries, data, vmin, scale, chunk=16)
        rv, ri = topk_from_chunks(cm, ca, 8)
        kv, ki = scan_topk_quantized_batched(
            queries, data, vmin, scale, jnp.ones((qn, m), bool), k=8, chunk=16)
        # the wrapper rescores top chunks exactly, so it can only be ≥ the
        # one-survivor-per-chunk oracle; top-1 must agree bit-for-bit
        np.testing.assert_allclose(np.asarray(kv[:, 0]), np.asarray(rv[:, 0]),
                                   rtol=2e-5, atol=2e-5)
        assert np.array_equal(np.asarray(ki[:, 0]), np.asarray(ri[:, 0]))
        assert bool(jnp.all(kv[:, :-1] >= kv[:, 1:]))       # descending

    def test_delta_scan_matches_brute_force(self, rng):
        """Quantized delta scan + fp32 rescore == exact brute force (the
        delta is smaller than k + margin, so rescore covers every row)."""
        d = 32
        v = _corpus(rng, 20, d)
        store = delta_mod.init(32, d, max_ids=64)
        store = delta_mod.insert(store, jnp.asarray(v), jnp.arange(20))
        q = jnp.asarray(_corpus(rng, 5, d))
        dv, di = delta_mod._scan_delta(store, q, k=5)
        bv, bi = ivf_mod.brute_force(jnp.asarray(v), jnp.ones(20, bool),
                                     jnp.arange(20), q, k=5)
        np.testing.assert_allclose(np.asarray(dv), np.asarray(bv),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_array_equal(np.asarray(di), np.asarray(bi))

    def test_search_sharded_single_device(self, rng):
        """1-shard mesh: sharded search (kernel path inside shard_map) must
        reproduce the local result bit-for-bit."""
        from jax.sharding import Mesh
        n, d = 512, 32
        v = _corpus(rng, n, d)
        idx, _ = ivf_mod.build(jax.random.PRNGKey(2), jnp.asarray(v),
                               jnp.arange(n), n_partitions=8, bits=8)
        leaves = jax.tree_util.tree_map(lambda a: a[None], idx)
        mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
        q = jnp.asarray(v[:8])
        sv, si = ivf_mod.search_sharded(leaves, q, mesh, n_probe=8, k=5)
        se, ie = ivf_mod.search(idx, q, n_probe=8, k=5)
        np.testing.assert_allclose(np.asarray(sv), np.asarray(se))
        np.testing.assert_array_equal(np.asarray(si), np.asarray(ie))

    def test_delta_tombstones_respected(self, rng):
        d = 16
        v = _corpus(rng, 8, d)
        store = delta_mod.init(16, d, max_ids=32)
        store = delta_mod.insert(store, jnp.asarray(v), jnp.arange(8))
        store = delta_mod.delete(store, jnp.asarray([0, 3]))
        _, di = delta_mod._scan_delta(store, jnp.asarray(v), k=8)
        assert not np.any(np.isin(np.asarray(di), [0, 3]))


class TestSparseFusion:
    def _dense_reference(self, vs, vi, graph_scores, wv, wg, n_nodes, k_fuse):
        """The pre-refactor dense formulation, verbatim."""
        sim_full = jnp.full((vs.shape[0], n_nodes), -jnp.inf)
        rows = jnp.arange(vs.shape[0])[:, None]
        sim_full = sim_full.at[rows, jnp.clip(vi, 0, n_nodes - 1)].set(
            jnp.where(vi >= 0, vs, -jnp.inf))
        w = FusionWeights(wv, wg)
        return fuse_topk(sim_full, graph_scores, w, k_fuse)

    def test_sparse_equals_dense_fuse_topk(self, rng):
        q_n, n = 6, 400
        sim = jnp.asarray(rng.normal(size=(q_n, n)).astype(np.float32))
        sim = jnp.where(jnp.asarray(rng.random((q_n, n)) < 0.9), -jnp.inf, sim)
        g = jnp.asarray(np.abs(rng.normal(size=(q_n, n))).astype(np.float32))
        w = FusionWeights(jnp.full((q_n,), 0.6), jnp.full((q_n,), 0.4))
        dv, dp = fuse_topk(sim, g, w, 10)
        sv, sp = fuse_topk_sparse(sim, g, w, 10,
                                  graph_max=jnp.max(g, axis=1, keepdims=True),
                                  valid=jnp.ones((q_n, n), bool))
        np.testing.assert_allclose(np.asarray(sv), np.asarray(dv), rtol=1e-6)
        np.testing.assert_array_equal(np.asarray(sp), np.asarray(dp))

    def test_candidate_union_equals_dense(self, rng):
        q_n, n, k_seed, k = 5, 500, 12, 6
        vs = jnp.sort(jnp.asarray(
            rng.random((q_n, k_seed)).astype(np.float32)), axis=1)[:, ::-1]
        vi = jnp.asarray(
            np.stack([rng.choice(n, k_seed, replace=False)
                      for _ in range(q_n)]).astype(np.int32))
        g = jnp.asarray(np.abs(rng.normal(size=(q_n, n))).astype(np.float32))
        wv = jnp.full((q_n,), 0.55)
        wg = jnp.full((q_n,), 0.45)
        k_fuse = 4 * k
        dv, di = self._dense_reference(vs, vi, g, wv, wg, n, k_fuse)
        sv, si = index_mod._fuse_candidates(vs, vi, g, wv, wg, k_fuse=k_fuse,
                                            frontier=k_fuse + k_seed)
        np.testing.assert_allclose(np.asarray(sv), np.asarray(dv),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_array_equal(np.asarray(si), np.asarray(di))

    def test_candidate_union_with_invalid_seeds(self, rng):
        q_n, n, k_seed = 3, 300, 8
        vs = jnp.asarray(rng.random((q_n, k_seed)).astype(np.float32))
        vi = jnp.asarray(
            np.stack([rng.choice(n, k_seed, replace=False)
                      for _ in range(q_n)]).astype(np.int32))
        vi = vi.at[:, -2:].set(-1)                           # padded seeds
        g = jnp.asarray(np.abs(rng.normal(size=(q_n, n))).astype(np.float32))
        sv, si = index_mod._fuse_candidates(
            vs, vi, g, jnp.full((q_n,), 0.6), jnp.full((q_n,), 0.4),
            k_fuse=10, frontier=40)
        assert bool(jnp.all(jnp.isfinite(sv)))
        assert bool(jnp.all(si >= 0))
        for row in np.asarray(si):
            assert len(set(row.tolist())) == len(row)        # no dupes

    def test_duplicate_seed_ids_collapse(self, rng):
        """NSW-refine merges can surface the same id twice in the seed list;
        fusion must keep one copy (as the dense scatter did), not let the
        duplicate displace the k-th result."""
        q_n, n, k_seed = 2, 100, 4
        vi = jnp.asarray([[7, 7, 3, 1], [5, 2, 5, 2]], jnp.int32)
        vs = jnp.asarray([[.9, .8, .7, .6], [.9, .8, .7, .6]], jnp.float32)
        g = jnp.asarray(np.abs(rng.normal(size=(q_n, n))).astype(np.float32))
        sv, si = index_mod._fuse_candidates(
            vs, vi, g, jnp.full((q_n,), 0.6), jnp.full((q_n,), 0.4),
            k_fuse=4, frontier=8)
        for row in np.asarray(si):
            assert len(set(row.tolist())) == len(row), row

    def test_fusion_stage_memory_independent_of_n_nodes(self):
        """Structural check of the memory claim: no intermediate in the
        fusion jaxpr is sized by n_nodes (only the graph_scores *input* is
        dense; every equation output is candidate-width)."""
        q_n, n_nodes, k_seed = 4, 3331, 12   # distinctive corpus width
        k_fuse, frontier = 20, 32
        fn = functools.partial(index_mod._fuse_candidates,
                               k_fuse=k_fuse, frontier=frontier)
        jaxpr = jax.make_jaxpr(fn)(
            jnp.ones((q_n, k_seed)), jnp.ones((q_n, k_seed), jnp.int32),
            jnp.ones((q_n, n_nodes)), jnp.ones((q_n,)), jnp.ones((q_n,)))

        def walk(jx):
            for eqn in jx.eqns:
                for ov in eqn.outvars:
                    shape = getattr(ov.aval, "shape", ())
                    assert n_nodes not in shape, (eqn.primitive, shape)
                for v in eqn.params.values():
                    for sub in (v if isinstance(v, (list, tuple)) else [v]):
                        core = getattr(sub, "jaxpr", None)
                        if hasattr(sub, "eqns"):
                            walk(sub)
                        elif core is not None and hasattr(core, "eqns"):
                            walk(core)

        walk(jaxpr.jaxpr)

    def test_hybrid_search_end_to_end_sparse(self, rng):
        """hybrid_search through the facade stays consistent with a dense
        reference fusion of its own stage outputs."""
        from repro.configs import get_config
        from repro.core import HMGIIndex
        from repro.core import traversal as trav_mod
        from repro.core.fusion import adaptive_weights
        from repro.data.synthetic import make_corpus

        corpus = make_corpus(n_nodes=400, modality_dims={"text": 32}, seed=3)
        cfg = get_config("hmgi").replace(n_partitions=8, n_probe=8, top_k=5,
                                         kmeans_iters=4, delta_capacity=64)
        idx = HMGIIndex(cfg, seed=0)
        idx.ingest({"text": (corpus.node_ids["text"], corpus.vectors["text"])},
                   n_nodes=corpus.n_nodes,
                   edges=(corpus.src, corpus.dst, corpus.edge_type))
        q = corpus.vectors["text"][:6]
        k = 5
        hv, hi = idx.hybrid_search(q, "text", k=k, n_hops=2)

        # dense reference over the same stage-1/2 outputs
        qn = idx._norm_queries(q)
        k_seed = max(2 * k, k + 8)
        vs, vi = idx.search(qn, "text", k=k_seed, n_probe=cfg.n_probe)
        g = idx.graph._replace(edge_weight=idx.boosted_weights) \
            if idx.boosted_weights is not None else idx.graph
        gs = trav_mod.multi_hop_batch(g, vi, vs, n_hops=2)
        w = adaptive_weights(vs, base_wv=cfg.w_vector, base_wg=cfg.w_graph)
        k_fuse = max(k, min(4 * k, corpus.n_nodes))
        ref = TestSparseFusion()._dense_reference(
            vs, vi, gs, w.w_vector, w.w_graph, corpus.n_nodes, k_fuse)
        np.testing.assert_allclose(np.asarray(hv), np.asarray(ref[0][:, :k]),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_array_equal(np.asarray(hi), np.asarray(ref[1][:, :k]))
