"""Shared benchmark utilities: corpora registry, timing, baseline systems.

"Systems" compared (the paper's Table 4-7 competitors are closed-source
servers; we implement the *algorithmic* baselines they represent):
  hmgi        — full system: modality-aware IVF + delta + hybrid fusion
  monolithic  — single brute-force index (pure-vector-DB stand-in)
  decoupled   — separate vector search then graph filter, two round trips
                (the dual-database / federation baseline)
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Dict, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import HMGIIndex
from repro.core import ivf as ivf_mod
from repro.core import traversal as trav_mod
from repro.data.synthetic import MultimodalCorpus, ground_truth_topk, make_corpus, recall_at_k

# scaled-down stand-ins for the paper's datasets (name -> (n_nodes, dims))
DATASETS: Dict[str, dict] = {
    "sift1b-s": dict(n_nodes=8192, modality_dims={"image": 128}, primary="image"),
    "deep1b-s": dict(n_nodes=8192, modality_dims={"image": 96}, primary="image"),
    "dec-10k": dict(n_nodes=10_000, modality_dims={"text": 64, "audio": 80},
                    primary="text"),
    "mm-codex-s": dict(n_nodes=6144, modality_dims={"text": 64, "image": 96},
                       primary="text"),
}


def load_corpus(name: str, seed: int = 0) -> MultimodalCorpus:
    spec = dict(DATASETS[name])
    spec.pop("primary")
    return make_corpus(seed=seed, **spec)


def primary_mod(name: str) -> str:
    return DATASETS[name]["primary"]


def timeit(fn: Callable, *args, trials: int = 5, warmup: int = 2, **kw) -> float:
    """Median wall seconds per call."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    ts = []
    for _ in range(trials):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def total_compiles() -> int:
    """Distinct compiled signatures currently cached across the hot jitted
    entry points (staticcheck's HMG103 registry). Every bench row reports
    the running total so respecialisation shows up as a climbing
    ``n_compiles`` column long before the CI budget gate trips."""
    try:
        from tools.staticcheck.registry import total_cache_size
    except ImportError:        # bench run outside the repo root
        return -1
    return total_cache_size()


def build_hmgi(corpus, *, bits=8, n_partitions=32, n_probe=8, seed=0,
               adaptive=True, **over):
    cfg = get_config("hmgi").replace(
        n_partitions=n_partitions, n_probe=n_probe, kmeans_iters=8,
        quant_bits=bits, adaptive_weights=adaptive, delta_capacity=512, **over)
    idx = HMGIIndex(cfg, seed=seed)
    idx.ingest({m: (corpus.node_ids[m], corpus.vectors[m])
                for m in corpus.vectors}, n_nodes=corpus.n_nodes,
               edges=(corpus.src, corpus.dst, corpus.edge_type))
    return idx


@dataclass
class Monolithic:
    """All modalities in one flat brute-force matrix (pure-vector baseline)."""
    vectors: jax.Array
    ids: jax.Array
    valid: jax.Array

    @classmethod
    def build(cls, corpus):
        vs, ids = [], []
        dmax = max(v.shape[1] for v in corpus.vectors.values())
        for m, v in corpus.vectors.items():
            v = v / np.maximum(np.linalg.norm(v, axis=1, keepdims=True), 1e-9)
            pad = np.zeros((len(v), dmax - v.shape[1]), np.float32)
            vs.append(np.concatenate([v, pad], 1))
            ids.append(corpus.node_ids[m])
        vs = np.concatenate(vs)
        ids = np.concatenate(ids)
        return cls(jnp.asarray(vs), jnp.asarray(ids),
                   jnp.ones((len(ids),), bool))

    def search(self, q, k=10):
        d = q.shape[1]
        qp = jnp.pad(jnp.asarray(q), ((0, 0), (0, self.vectors.shape[1] - d)))
        qp = qp / jnp.maximum(jnp.linalg.norm(qp, axis=1, keepdims=True), 1e-9)
        return ivf_mod.brute_force(self.vectors, self.valid, self.ids, qp, k=k)


class Decoupled:
    """Two-stage federation baseline: vector search round trip, then a
    separate graph-system round trip (sequential, unfused scores — the
    paper's dual-database architecture)."""

    def __init__(self, corpus, hmgi: HMGIIndex):
        self.hmgi = hmgi
        self.graph = hmgi.graph

    def hybrid_search(self, q, modality, k=10, n_hops=2):
        # round trip 1: vector store
        vs, vi = self.hmgi.search(q, modality, k=k)
        jax.block_until_ready(vs)          # federation boundary (serialize)
        # round trip 2: graph store expansion, unweighted re-rank
        gs = trav_mod.multi_hop_batch(self.graph, vi, vs, n_hops=n_hops)
        jax.block_until_ready(gs)
        # naive post-hoc combine (no adaptive fusion)
        rows = jnp.arange(q.shape[0])[:, None]
        base = jnp.full((q.shape[0], self.graph.n_nodes), -jnp.inf)
        base = base.at[rows, jnp.clip(vi, 0, self.graph.n_nodes - 1)].set(vs)
        comb = jnp.where(jnp.isfinite(base), base, 0.0) + gs
        comb = jnp.where(jnp.isfinite(base) | (gs > 0), comb, -jnp.inf)
        vals, ids = jax.lax.top_k(comb, k)
        return vals, ids


def make_queries(corpus, modality, n=64, seed=3, noise=0.05):
    rng = np.random.default_rng(seed)
    v = corpus.vectors[modality]
    sel = rng.integers(0, len(v), n)
    return (v[sel] + noise * rng.normal(size=(n, v.shape[1]))).astype(np.float32)
