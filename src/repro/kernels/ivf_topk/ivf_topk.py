"""Fused quantized-scan + partial-top-k Pallas kernel (the IVF hot loop).

Computes, for a query block against a quantized corpus slab:

    score[q, n] = scale[n] · (Q[q] · D_int8[n]) + (128·scale[n] + vmin[n]) · Σ_d Q[q,d]

(the affine-dequant identity — int8 rows never materialise as fp32 in HBM),
then reduces each ``chunk`` of consecutive rows to its (max, argmax). The
final exact top-k over (N/chunk) survivors happens outside in jnp — survivors
are tiny. This is the TPU-native ANN layout (partial-reduce scan; cf.
"TPU-KNN at Peak FLOP/s"): all FLOPs are one MXU matmul per (query-block ×
row-block), HBM traffic is int8, and no sort runs inside the kernel.

VMEM budget per grid step (defaults bq=256, bn=512, d≤1024, fp32 scores):
  Q block 256·d·4 ≤ 1 MB, D block 512·d ≤ 0.5 MB (int8), scores 256·512·4
  = 0.5 MB, outputs 2·256·(512/chunk)·4 — comfortably inside 16 MB VMEM,
  MXU dims (256×d)·(d×512) aligned to the 128-lane systolic array.

Two entry points share the kernel math:

  scan_topk_pallas         — one corpus slab shared by every query (the
                             delta-store scan, monolithic baselines).
  scan_topk_pallas_batched — per-query slabs (Q, M, d): the IVF probe path,
                             where each query gathered its own probed
                             partitions as contiguous row blocks of the
                             flattened (K·cap, d) index slab. The grid runs
                             over row blocks only; the query axis stays inside
                             one batched dot_general per step, so interpret
                             mode pays O(M/block_n) interpreter steps, not
                             O(Q·M/block_n).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(q_ref, qsum_ref, d_ref, aff_ref, scale_ref, bias_ref,
            smax_ref, sarg_ref, *, chunk: int, block_n: int):
    # q_ref:    (bq, d)      fp32   — query block (resident across grid)
    # qsum_ref: (bq, 1)      fp32   — per-query Σ_d q
    # d_ref:    (bn, d)      int8   — corpus rows for this grid step
    # aff_ref:  (bn, 1)      fp32   — 128·scale + vmin   (affine term)
    # scale_ref:(bn, 1)      fp32
    # bias_ref: (bn, 1)      fp32   — 0 for live rows, -3e38 for masked rows
    # smax_ref: (bq, bn/chunk) fp32 — per-chunk max scores (output block)
    # sarg_ref: (bq, bn/chunk) int32 — per-chunk argmax (row within slab)
    n = pl.program_id(0)
    q = q_ref[...]
    d = d_ref[...].astype(jnp.float32)
    dots = jax.lax.dot_general(q, d, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)   # (bq, bn)
    scores = (dots * scale_ref[...][:, 0][None, :]
              + qsum_ref[...] * aff_ref[...][:, 0][None, :]
              + bias_ref[...][:, 0][None, :])
    bq = scores.shape[0]
    nchunks = block_n // chunk
    sc = scores.reshape(bq, nchunks, chunk)
    smax_ref[...] = jnp.max(sc, axis=-1)
    arg = jnp.argmax(sc, axis=-1).astype(jnp.int32)                  # (bq, nchunks)
    base = n * block_n + jnp.arange(nchunks, dtype=jnp.int32) * chunk
    sarg_ref[...] = arg + base[None, :]


def scan_topk_pallas(queries, data_i8, vmin, scale, bias=None, *,
                     chunk: int = 128, block_n: int = 512,
                     interpret: bool = False):
    """queries (Q, d) fp32; data_i8 (N, d) int8 (centered at -128);
    vmin/scale (N,) fp32; bias (N,) fp32 or None (0 live, -3e38 masked).
    Returns (chunk_max (Q, N/chunk), chunk_arg)."""
    qn, d = queries.shape
    n = data_i8.shape[0]
    assert n % block_n == 0 and block_n % chunk == 0, (n, block_n, chunk)
    nchunks_total = n // chunk
    nblocks = n // block_n
    per_block = block_n // chunk

    qsum = jnp.sum(queries, axis=-1, keepdims=True)                  # (Q, 1)
    aff = (128.0 * scale + vmin).reshape(n, 1)
    scale2 = scale.reshape(n, 1)
    bias2 = (jnp.zeros((n, 1), jnp.float32) if bias is None
             else bias.reshape(n, 1).astype(jnp.float32))

    out_shapes = (
        jax.ShapeDtypeStruct((qn, nchunks_total), jnp.float32),
        jax.ShapeDtypeStruct((qn, nchunks_total), jnp.int32),
    )
    grid = (nblocks,)
    return pl.pallas_call(
        functools.partial(_kernel, chunk=chunk, block_n=block_n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((qn, d), lambda i: (0, 0)),                  # queries
            pl.BlockSpec((qn, 1), lambda i: (0, 0)),                  # qsum
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),             # data
            pl.BlockSpec((block_n, 1), lambda i: (i, 0)),             # affine
            pl.BlockSpec((block_n, 1), lambda i: (i, 0)),             # scale
            pl.BlockSpec((block_n, 1), lambda i: (i, 0)),             # bias
        ],
        out_specs=(
            pl.BlockSpec((qn, per_block), lambda i: (0, i)),
            pl.BlockSpec((qn, per_block), lambda i: (0, i)),
        ),
        out_shape=out_shapes,
        interpret=interpret,
    )(queries.astype(jnp.float32), qsum, data_i8, aff, scale2, bias2)


def _kernel_batched(q_ref, qsum_ref, d_ref, aff_ref, scale_ref, bias_ref,
                    smax_ref, sarg_ref, *, chunk: int, block_n: int):
    # q_ref:    (bq, d)          fp32 — query block (resident across grid)
    # qsum_ref: (bq, 1)          fp32 — per-query Σ_d q
    # d_ref:    (bq, bn, d)      int8 — each query's own slab rows
    # aff/scale/bias_ref: (bq, bn) fp32 — per-(query, row) dequant terms
    # smax/sarg_ref: (bq, bn/chunk) — per-chunk (max, argmax) output block
    n = pl.program_id(0)
    q = q_ref[...][:, None, :]                                        # (bq,1,d)
    d = d_ref[...].astype(jnp.float32)                                # (bq,bn,d)
    dots = jax.lax.dot_general(q, d, (((2,), (2,)), ((0,), (0,))),
                               preferred_element_type=jnp.float32)[:, 0, :]
    scores = (dots * scale_ref[...] + qsum_ref[...] * aff_ref[...]
              + bias_ref[...])                                        # (bq, bn)
    bq = scores.shape[0]
    nchunks = block_n // chunk
    sc = scores.reshape(bq, nchunks, chunk)
    smax_ref[...] = jnp.max(sc, axis=-1)
    base = n * block_n + jnp.arange(nchunks, dtype=jnp.int32) * chunk
    sarg_ref[...] = jnp.argmax(sc, axis=-1).astype(jnp.int32) + base[None, :]


def scan_topk_pallas_batched(queries, data_i8, vmin, scale, bias=None, *,
                             chunk: int = 16, block_n: int = 512,
                             interpret: bool = False):
    """Per-query-slab variant: queries (Q, d) fp32; data_i8 (Q, M, d) int8
    (centered at -128); vmin/scale/bias (Q, M) fp32. Returns
    (chunk_max (Q, M/chunk), chunk_arg) — chunk_arg indexes rows of each
    query's own slab.

    VMEM per grid step is Q·block_n·d·5 bytes for the data block — int8
    storage plus the fp32 cast the matmul consumes (the whole query axis
    rides along). The probe path sizes block_n from an ~8 MB budget (see
    ``core/ivf.py:_probe_block_n``); callers picking block_n by hand should
    keep Q·block_n·d·5 well under the 16 MB/core VMEM.
    """
    qn, d = queries.shape
    m = data_i8.shape[1]
    assert m % block_n == 0 and block_n % chunk == 0, (m, block_n, chunk)
    nblocks = m // block_n
    nchunks_total = m // chunk
    per_block = block_n // chunk

    qsum = jnp.sum(queries.astype(jnp.float32), axis=-1, keepdims=True)
    aff = 128.0 * scale + vmin                                        # (Q, M)
    bias2 = (jnp.zeros((qn, m), jnp.float32) if bias is None
             else bias.astype(jnp.float32))

    out_shapes = (
        jax.ShapeDtypeStruct((qn, nchunks_total), jnp.float32),
        jax.ShapeDtypeStruct((qn, nchunks_total), jnp.int32),
    )
    return pl.pallas_call(
        functools.partial(_kernel_batched, chunk=chunk, block_n=block_n),
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((qn, d), lambda i: (0, 0)),                  # queries
            pl.BlockSpec((qn, 1), lambda i: (0, 0)),                  # qsum
            pl.BlockSpec((qn, block_n, d), lambda i: (0, i, 0)),      # data
            pl.BlockSpec((qn, block_n), lambda i: (0, i)),            # affine
            pl.BlockSpec((qn, block_n), lambda i: (0, i)),            # scale
            pl.BlockSpec((qn, block_n), lambda i: (0, i)),            # bias
        ],
        out_specs=(
            pl.BlockSpec((qn, per_block), lambda i: (0, i)),
            pl.BlockSpec((qn, per_block), lambda i: (0, i)),
        ),
        out_shape=out_shapes,
        interpret=interpret,
    )(queries.astype(jnp.float32), qsum, data_i8, aff, scale, bias2)
