"""xdeepfm [recsys] — CIN + MLP over sparse embedding fields.  [arXiv:1803.05170]"""
from repro.configs.base import RecsysConfig, ShapeSpec

CONFIG = RecsysConfig(
    arch_id="xdeepfm",
    source="arXiv:1803.05170; paper",
    n_sparse=39,
    embed_dim=10,
    vocab_per_field=100_000,   # Criteo-like scale per field (assignment leaves it open)
    cin_layers=(200, 200, 200),
    mlp_layers=(400, 400),
)

SHAPES = [
    ShapeSpec("train_batch", "train", {"batch": 65536}),
    ShapeSpec("serve_p99", "serve", {"batch": 512}),
    ShapeSpec("serve_bulk", "serve", {"batch": 262144}),
    ShapeSpec("retrieval_cand", "retrieval", {"batch": 1, "n_candidates": 1_000_000}),
]
