from repro.kernels.ivf_topk.ops import (scan_topk_quantized,
                                        scan_topk_quantized_batched)
