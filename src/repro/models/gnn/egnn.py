"""EGNN — E(n)-equivariant GNN (Satorras et al., arXiv:2102.09844).

    m_ij   = φ_e(h_i, h_j, ‖x_i − x_j‖²)
    x_i'   = x_i + C·Σ_j (x_i − x_j)·φ_x(m_ij)
    h_i'   = φ_h(h_i, Σ_j m_ij)

Invariance comes only from scalar distances — the cheap-equivariant regime
of the kernel taxonomy (no spherical harmonics).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common.params import Builder


def _mlp(b: Builder, name: str, dims, axes_last="hidden"):
    sub = b.sub()
    for i, (di, do) in enumerate(zip(dims[:-1], dims[1:])):
        sub.dense(f"w{i}", (di, do), (None, axes_last if i < len(dims) - 2 else None),
                  fan_in=di)
        sub.zeros(f"b{i}", (do,), (None,))
    b.child(name, sub)
    return len(dims) - 1


def _apply_mlp(p, x, n_layers: int, act=jax.nn.silu, final_act=False):
    for i in range(n_layers):
        x = x @ p[f"w{i}"] + p[f"b{i}"]
        if i < n_layers - 1 or final_act:
            x = act(x)
    return x


def init(cfg, key, d_feat_in: int, n_out: int):
    d = cfg.d_hidden
    b = Builder(key, dtype=jnp.float32)
    b.dense("enc", (d_feat_in, d), (None, "hidden"), fan_in=d_feat_in)
    layers = []
    for _ in range(cfg.n_layers):
        lb = b.sub()
        _mlp(lb, "phi_e", (2 * d + 1, d, d))
        _mlp(lb, "phi_x", (d, d, 1))
        _mlp(lb, "phi_h", (2 * d, d, d))
        layers.append(lb.build())
    b.params["layers"] = [p for p, _ in layers]
    b.axes["layers"] = [a for _, a in layers]
    b.dense("head", (d, n_out), (None, None), fan_in=d)
    return b.build()


def apply(cfg, params, feats, positions, node_mask, ex):
    """Returns (node_embeddings (N, d), new_positions)."""
    d = cfg.d_hidden
    h = feats @ params["enc"]
    x = positions
    for lp in params["layers"]:
        payload = jnp.concatenate([h, x], axis=-1)          # (N, d+3)

        def msg_fn(srcs, dsts, lp=lp):
            hs, xs = srcs[:, :d], srcs[:, d:]
            hd, xd = dsts[:, :d], dsts[:, d:]
            rel = xd - xs                                   # x_i - x_j (i = dst)
            r2 = jnp.sum(rel * rel, axis=-1, keepdims=True)
            m = _apply_mlp(lp["phi_e"], jnp.concatenate([hd, hs, r2], -1), 2,
                           final_act=True)                  # (E, d)
            cw = jnp.tanh(_apply_mlp(lp["phi_x"], m, 2))    # (E, 1) bounded
            return jnp.concatenate([m, rel * cw, jnp.ones_like(cw)], axis=-1)

        agg = ex.push(payload, msg_fn, d + 3 + 1)
        m_sum, x_upd, cnt = agg[:, :d], agg[:, d:d + 3], agg[:, d + 3:]
        h = h + _apply_mlp(lp["phi_h"], jnp.concatenate([h, m_sum], -1), 2)
        x = x + x_upd / jnp.maximum(cnt, 1.0)
        h = h * node_mask[:, None]
    return h, x


def node_logits(cfg, params, feats, positions, node_mask, ex):
    h, _ = apply(cfg, params, feats, positions, node_mask, ex)
    return h @ params["head"]
