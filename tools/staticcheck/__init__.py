"""Repo-invariant static analysis for the HMGI codebase.

Two layers, one CLI (``python -m tools.staticcheck``):

**Layer 1 — Python-AST lints** (stdlib ``ast``; the checked modules are
never imported): named rules encoding invariants this repo has already paid
to learn, each one keyed to the PR that fixed the bug it prevents
(docs/DESIGN.md §8):

  HMG001  no host-sync ops inside traced functions of hot-path modules
  HMG002  recompile hazards: data-dependent Python ints reaching static
          shape args of jitted entry points without pow2/chunk padding
  HMG003  MVCC discipline: scan entry points must thread the visibility /
          ``node_pass`` kwargs explicitly
  HMG004  persistence ordering: fsync-before-rename, WAL append-before-apply
  HMG000  pragma discipline: ``# staticcheck: disable=RULE (reason)`` —
          the reason is mandatory; a bare disable is itself a violation

**Layer 2 — trace-level analysis** (imports jax + the repo): the registry
(``tools/staticcheck/registry.py``) names hot jitted entry points with
canonical shapes; each is traced to a jaxpr and linted:

  HMG101  slab-scale int8 -> f32 ``convert_element_type`` inside the int8
          scan lane before the rescore boundary (HBM dequant regression)
  HMG102  ``device_put`` / host-callback transfer ops inside a traced region
  HMG103  compile-count budget: the canonical mixed workload must not
          compile more distinct signatures per entry point than
          ``tools/staticcheck/budgets.json`` allows

Suppression: append ``# staticcheck: disable=HMG003 (why it is safe here)``
to the offending line (or the line directly above it). The reason is
required. ``--fix`` normalises malformed pragmas and inserts provably
default-equivalent missing kwargs for HMG003.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional


@dataclasses.dataclass(frozen=True)
class Violation:
    rule: str          # "HMG001", ...
    path: str          # repo-relative file (or entry name for trace rules)
    line: int          # 1-based; 0 when the finding has no source anchor
    message: str
    fixable: bool = False

    def format(self) -> str:
        loc = f"{self.path}:{self.line}" if self.line else self.path
        return f"{loc}: {self.rule}: {self.message}"


def sort_violations(vs: List[Violation]) -> List[Violation]:
    return sorted(vs, key=lambda v: (v.rule, v.path, v.line))
