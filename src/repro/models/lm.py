"""Transformer LM covering the five assigned LM archs (GQA/MLA, dense/MoE,
SWA, QKV-bias, tied/untied embeddings), with scanned + rematerialised layers,
vocab-sharded cross-entropy, prefill KV-cache production and one-token decode.

All functions are pure; parameters are pytrees built by ``init_lm`` (and its
``jax.eval_shape`` for the multi-pod dry-run). ``ExecOpts`` carries execution
knobs (q-block size, unroll for cost-analysis-accurate dry-runs).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.params import Builder, stack_layers
from repro.layers.attention import gqa_forward, init_gqa
from repro.layers.mla import init_mla, mla_forward
from repro.layers.mlp import init_swiglu, swiglu
from repro.layers.moe import init_moe, moe_ffn
from repro.layers.norms import rms_norm
from repro.sharding.rules import with_sharding
from repro.train.optimizer import AdamWConfig, adamw_update, init_adamw


@dataclasses.dataclass(frozen=True)
class ExecOpts:
    q_block: int = 1024
    unroll_layers: bool = False     # dry-run sets True (cost-analysis accuracy)
    unroll_attn_blocks: bool = False
    remat: bool = True
    aux_loss_weight: float = 0.01
    # §Perf iteration 1 (EXPERIMENTS.md): cotangents cross TP/DP collective
    # boundaries in bf16 instead of f32 (halves the dominant collective term)
    bf16_grad_barrier: bool = True


@jax.custom_vjp
def _bf16_barrier(x):
    """Identity fwd; casts the cotangent to bf16 (placed at layer boundaries
    so backward TP all-reduces move half the bytes)."""
    return x


def _bf16_barrier_fwd(x):
    return x, None


def _bf16_barrier_bwd(_, g):
    return (g.astype(jnp.bfloat16).astype(g.dtype) if g.dtype == jnp.float32
            else g,)


# NOTE: casting bf16->f32 back would keep the f32 all-reduce; instead return
# the bf16 cotangent directly (JAX allows dtype-changing cotangents only via
# the primal dtype, so we cast the *primal* path: see barrier_apply below).
def _bf16_barrier_bwd_strict(_, g):
    return (g.astype(jnp.bfloat16),)


_bf16_barrier.defvjp(_bf16_barrier_fwd, _bf16_barrier_bwd_strict)


def barrier_apply(x, opts):
    """bf16 cotangent barrier: ensure the primal is bf16 here (it is, at layer
    boundaries) so the bf16 cotangent is type-correct."""
    if opts.bf16_grad_barrier and x.dtype == jnp.bfloat16:
        return _bf16_barrier(x)
    return x


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_layer(cfg, key, layer_idx: int):
    b = Builder(key, dtype=jnp.dtype(cfg.dtype))
    sub = b.sub()
    if cfg.attention == "mla":
        ap, aa = init_mla(cfg, sub.key())
    else:
        ap, aa = init_gqa(cfg, sub.key())
    b.params["attn"], b.axes["attn"] = ap, aa
    b.ones("ln1", (cfg.d_model,), (None,))
    b.ones("ln2", (cfg.d_model,), (None,))
    is_moe = cfg.moe and layer_idx >= cfg.first_dense_layers
    if is_moe:
        mp, ma = init_moe(cfg, sub.key())
        b.params["moe"], b.axes["moe"] = mp, ma
        if cfg.n_shared_experts:
            sp, sa = init_swiglu(cfg, sub.key(),
                                 d_ff=cfg.n_shared_experts * (cfg.moe_d_ff or cfg.d_ff))
            b.params["shared"], b.axes["shared"] = sp, sa
    else:
        f = (cfg.dense_d_ff or cfg.d_ff) if cfg.moe else cfg.d_ff
        fp, fa = init_swiglu(cfg, sub.key(), d_ff=f)
        b.params["ffn"], b.axes["ffn"] = fp, fa
    return b.build()


def init_lm(cfg, key):
    """Returns (params, logical_axes) with layers stacked for scan."""
    b = Builder(key, dtype=jnp.dtype(cfg.dtype))
    if getattr(cfg, "tie_embeddings", False):
        b.dense("embed", (cfg.vocab_size, cfg.d_model), ("vocab", None), fan_in=cfg.d_model)
    else:
        b.dense("embed", (cfg.vocab_size, cfg.d_model), (None, "embed_model"),
                fan_in=cfg.d_model)
        b.dense("head", (cfg.d_model, cfg.vocab_size), (None, "vocab"), fan_in=cfg.d_model)
    b.ones("final_ln", (cfg.d_model,), (None,))

    keys = jax.random.split(b.key(), cfg.n_layers)
    head_layers = []
    for i in range(cfg.first_dense_layers):
        head_layers.append(_init_layer(cfg, keys[i], i))
    if head_layers:
        b.params["head_layers"] = [p for p, _ in head_layers]
        b.axes["head_layers"] = [a for _, a in head_layers]
    scanned = [_init_layer(cfg, keys[i], i)
               for i in range(cfg.first_dense_layers, cfg.n_layers)]
    b.params["layers"], b.axes["layers"] = stack_layers(scanned)
    return b.build()


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _attn(cfg):
    return mla_forward if cfg.attention == "mla" else gqa_forward


def _layer_fwd(cfg, opts: ExecOpts, mesh, lp, x, positions, mode, cache_l,
               cache_pos, collect_cache: bool = True):
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    h, new_cache = _attn(cfg)(cfg, lp["attn"], h, positions, mode=mode,
                              cache=cache_l, cache_pos=cache_pos, mesh=mesh,
                              q_block=opts.q_block,
                              unroll_blocks=opts.unroll_attn_blocks)
    if not collect_cache:
        new_cache = None   # training: don't stack per-layer KV as scan outputs
    x = x + h
    hn = rms_norm(x, lp["ln2"], cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if "moe" in lp:
        out, aux = moe_ffn(cfg, lp["moe"], hn, mesh,
                           capacity_factor=cfg.capacity_factor)
        if "shared" in lp:
            out = out + swiglu(lp["shared"], hn)
    else:
        out = swiglu(lp["ffn"], hn)
    x = x + out
    x = with_sharding(x, ("batch", "seq", None), mesh)
    x = barrier_apply(x, opts)
    return x, new_cache, aux


def _run_layers(cfg, opts, mesh, params, x, positions, mode, cache, cache_pos,
                collect_cache: bool = True):
    """Applies head (unstacked) layers then the scanned stack.

    cache: pytree with leading L dim per leaf (or None). Returns (x, new_cache, aux).
    """
    aux_total = jnp.zeros((), jnp.float32)
    n_head = cfg.first_dense_layers
    head_caches = []
    for i in range(n_head):
        cl = jax.tree.map(lambda c: c[i], cache) if cache is not None else None
        x, nc, aux = _layer_fwd(cfg, opts, mesh, params["head_layers"][i],
                                x, positions, mode, cl, cache_pos, collect_cache)
        head_caches.append(nc)
        aux_total = aux_total + aux

    body_fn = partial(_layer_fwd, cfg, opts, mesh)

    def scan_body(carry, scanned):
        x, aux_total = carry
        lp, cl = scanned
        x, nc, aux = body_fn(lp, x, positions, mode, cl, cache_pos, collect_cache)
        return (x, aux_total + aux), nc

    if opts.remat and mode == "full":
        # prevent_cse=False: safe inside scan (the loop boundary already
        # prevents the problematic CSE) and avoids the optimization barriers
        # that defeat XLA buffer reuse
        scan_body = jax.checkpoint(scan_body, prevent_cse=False)

    n_scan = cfg.n_layers - n_head
    scan_cache = (jax.tree.map(lambda c: c[n_head:], cache)
                  if cache is not None else _dummy_cache(cfg, x, n_scan, mode))
    (x, aux_total), new_scan_cache = jax.lax.scan(
        scan_body, (x, aux_total), (params["layers"], scan_cache),
        unroll=n_scan if opts.unroll_layers else 1)

    if not collect_cache:
        return x, None, aux_total
    if head_caches and head_caches[0] is not None:
        stacked_head = jax.tree.map(lambda *cs: jnp.stack(cs, 0), *head_caches)
        new_cache = jax.tree.map(lambda h, r: jnp.concatenate([h, r], axis=0),
                                 stacked_head, new_scan_cache)
    else:
        new_cache = new_scan_cache
    return x, new_cache, aux_total


def _dummy_cache(cfg, x, n_scan, mode):
    # "full" mode ignores input caches; scan needs a scannable placeholder.
    return None if mode != "full" else None


def forward(cfg, params, tokens, mesh=None, opts: ExecOpts = ExecOpts()):
    """Training forward: tokens (B, S) -> logits (B, S, V[sharded])."""
    x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    x = with_sharding(x, ("batch", "seq", None), mesh)
    positions = jnp.arange(tokens.shape[1])
    x, _, aux = _run_layers(cfg, opts, mesh, params, x, positions, "full",
                            None, None, collect_cache=False)
    x = rms_norm(x, params["final_ln"], cfg.norm_eps)
    if getattr(cfg, "tie_embeddings", False):
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(x.dtype))
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["head"].astype(x.dtype))
    logits = with_sharding(logits, ("batch", "seq", "vocab_act"), mesh)
    return logits, aux


def xent_loss(cfg, logits, labels):
    """Vocab-sharded cross-entropy: no gather over the sharded vocab dim."""
    lf = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(lf, axis=-1, keepdims=True))
    lse = jnp.log(jnp.sum(jnp.exp(lf - m), axis=-1)) + m[..., 0]
    onehot = (jnp.arange(lf.shape[-1])[None, None, :] == labels[..., None])
    label_logit = jnp.sum(jnp.where(onehot, lf, 0.0), axis=-1)
    return jnp.mean(lse - label_logit)


def loss_fn(cfg, params, batch, mesh=None, opts: ExecOpts = ExecOpts()):
    logits, aux = forward(cfg, params, batch["tokens"], mesh, opts)
    loss = xent_loss(cfg, logits, batch["labels"])
    return loss + opts.aux_loss_weight * aux, {"xent": loss, "aux": aux}


# ---------------------------------------------------------------------------
# train step
# ---------------------------------------------------------------------------

def make_train_step(cfg, mesh=None, opts: ExecOpts = ExecOpts(),
                    opt_cfg: AdamWConfig = AdamWConfig(), grad_accum: int = 1):
    """grad_accum > 1: batch arrives pre-shaped (accum, micro_batch, seq) —
    microbatches run sequentially (lax.scan) with fp32 gradient accumulation,
    bounding stored activations to one microbatch (the production pattern for
    large global batches on small-HBM parts)."""
    def grad_of(params, batch):
        return jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch, mesh, opts), has_aux=True)(params)

    def train_step(params, opt_state, batch):
        if grad_accum == 1:
            (loss, parts), grads = grad_of(params, batch)
        else:
            def mb(carry, mbatch):
                gsum, lsum = carry
                (l, _), g = grad_of(params, mbatch)
                gsum = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                                    gsum, g)
                return (gsum, lsum + l), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(mb, (g0, jnp.zeros(())), batch)
            grads = jax.tree.map(lambda g: g / grad_accum, gsum)
            loss = lsum / grad_accum
            parts = {}
        params, opt_state, om = adamw_update(opt_cfg, grads, opt_state, params)
        metrics = {"loss": loss, **parts, **om}
        return params, opt_state, metrics
    return train_step


# ---------------------------------------------------------------------------
# serving: prefill + decode
# ---------------------------------------------------------------------------

def cache_len_for(cfg, seq_len: int) -> int:
    return min(seq_len, cfg.sliding_window) if cfg.sliding_window else seq_len


def init_cache(cfg, batch: int, cache_len: int, dtype=None):
    """Abstract-friendly KV cache pytree (+ logical axes) with leading L dim."""
    dt = dtype or jnp.dtype(cfg.dtype)
    L = cfg.n_layers
    if cfg.attention == "mla":
        cache = (jnp.zeros((L, batch, cache_len, cfg.kv_lora_rank), dt),
                 jnp.zeros((L, batch, cache_len, cfg.qk_rope_head_dim), dt),
                 jnp.full((L, batch, cache_len), -(10 ** 9), jnp.int32))
        axes = ((None, "batch", "cache_seq", None),
                (None, "batch", "cache_seq", None),
                (None, "batch", None))
    else:
        hkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
        cache = (jnp.zeros((L, batch, cache_len, hkv, hd), dt),
                 jnp.zeros((L, batch, cache_len, hkv, hd), dt),
                 jnp.full((L, batch, cache_len), -(10 ** 9), jnp.int32))
        axes = ((None, "batch", "cache_seq", None, None),
                (None, "batch", "cache_seq", None, None),
                (None, "batch", None))
    return cache, axes


def prefill(cfg, params, tokens, mesh=None, opts: ExecOpts = ExecOpts(),
            margin: int = 0):
    """Processes a prompt; returns (last-token logits, cache pytree).

    ``margin`` reserves headroom in the returned cache for subsequent decode
    steps (full-attention archs; SWA caches roll in place regardless).
    """
    bsz, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    x = with_sharding(x, ("batch", "seq", None), mesh)
    positions = jnp.arange(s)
    pf_opts = dataclasses.replace(opts, remat=False)
    x, caches, _ = _run_layers(cfg, pf_opts, mesh, params, x, positions, "full",
                               None, None)
    x = rms_norm(x[:, -1:, :], params["final_ln"], cfg.norm_eps)
    if getattr(cfg, "tie_embeddings", False):
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(x.dtype))
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["head"].astype(x.dtype))

    # caches from "full" mode: per layer stacked (L, B, S, ...): convert to the
    # decode layout (truncate+roll to window for SWA so the rolling-slot
    # invariant slot == pos % clen holds; pad headroom otherwise; add slot_pos)
    clen = cache_len_for(cfg, s + margin)

    def fit(c):
        if c.shape[2] > clen:  # SWA truncation: keep last window, restore slot order
            return jnp.roll(c[:, :, -clen:], shift=s % clen, axis=2)
        if c.shape[2] < clen:
            pad = [(0, 0)] * c.ndim
            pad[2] = (0, clen - c.shape[2])
            return jnp.pad(c, pad)
        return c

    if clen < s:  # truncated+rolled
        slot_vals = jnp.roll(jnp.arange(s - clen, s, dtype=jnp.int32), s % clen)
    else:
        slot_vals = jnp.concatenate([
            jnp.arange(s, dtype=jnp.int32),
            jnp.full((clen - s,), -(10 ** 9), jnp.int32)])
    # per-sequence slot positions (L, B, clen): decode advances each batch
    # row at its own position (continuous batching over ragged prompts)
    slot_pos = jnp.broadcast_to(slot_vals[None, None, :],
                                (cfg.n_layers, bsz, clen))
    new_cache = tuple(fit(c) for c in caches) + (slot_pos,)
    return logits[:, 0], new_cache


def decode_step(cfg, params, cache, token, pos, mesh=None,
                opts: ExecOpts = ExecOpts()):
    """One decode step. token: (B,) int32; pos: scalar int32 (every sequence
    at the same position) or (B,) int32 (per-sequence positions — the
    continuous-batching case, where ragged prompts put each cache row at its
    own length). Each row writes KV at its own slot and attends only to its
    own history.

    Returns (logits (B, V[sharded]), new_cache).
    """
    x = jnp.take(params["embed"], token[:, None], axis=0).astype(jnp.dtype(cfg.dtype))
    x = with_sharding(x, ("batch", "seq", None), mesh)
    pos_b = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1),
                             (token.shape[0],))          # (B,)
    positions = pos_b[:, None]                           # (B, 1)
    dec_opts = dataclasses.replace(opts, remat=False)
    x, new_cache, _ = _run_layers(cfg, dec_opts, mesh, params, x, positions,
                                  "decode", cache, pos_b)
    x = rms_norm(x, params["final_ln"], cfg.norm_eps)
    if getattr(cfg, "tie_embeddings", False):
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(x.dtype))
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["head"].astype(x.dtype))
    logits = with_sharding(logits, ("batch", "seq", "vocab_act"), mesh)
    return logits[:, 0], new_cache
