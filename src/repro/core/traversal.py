"""Graph traversal as masked frontier expansion (the Cypher-traversal analogue).

An h-hop traversal from a weighted seed set is h ``segment_sum`` pushes over
the COO edge list — fixed shapes, no dynamic worklists, MXU/VPU friendly, and
exactly the quantity Eq. 3's graph term needs: ``s_gi`` is the (normalised)
seed mass reaching node i at hop g.

Edge-type filters (Cypher's ``[:REL_TYPE]``) and per-hop damping are masks —
predicate-agnostic in NaviX's sense: any boolean edge/node predicate composes.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.graph_store import GraphStore, edge_type_lut


class TraversalResult(NamedTuple):
    per_hop: jax.Array    # (h, N) fp32 — mass arriving at each node per hop
    total: jax.Array      # (N,) fp32 — mean over hops (Eq. 3's (1/h)·Σ s_g)


def as_edge_mask(edge_type_mask) -> Optional[jax.Array]:
    """Normalises the two spellings of an edge-type filter: a (T,) mask
    array (indexed by edge type) passes through; an iterable of edge-type
    ids — the query engine's ``Traverse(edge_types=…)`` — compiles to one
    via ``graph_store.edge_type_lut``. Edge types ≥ T read as excluded."""
    if edge_type_mask is None or hasattr(edge_type_mask, "shape"):
        return edge_type_mask
    return edge_type_lut(edge_type_mask)


def frontier_expand(g: GraphStore, seed_scores: jax.Array, *, n_hops: int,
                    edge_type_mask: Optional[jax.Array] = None,
                    node_mask: Optional[jax.Array] = None,
                    damping: float = 0.85,
                    top_m: int = 0) -> TraversalResult:
    """seed_scores: (N,) fp32 (zeros except seeds). Returns per-hop node mass.

    node_mask: optional (N,) bool — the node-predicate analogue of the edge
    mask (Cypher's ``WHERE n.attr = v``): excluded nodes neither receive nor
    forward mass, so a filtered hybrid query never routes relevance through
    a node the predicate rules out (masked at every hop, not post-hoc).

    top_m > 0 prunes each hop's frontier to its m strongest nodes (the paper's
    pruning for >3-hop traversals; keeps cost bounded on power-law graphs).
    """
    n = g.n_nodes
    ew = g.edge_weight
    edge_type_mask = as_edge_mask(edge_type_mask)
    if edge_type_mask is not None:
        # safe gather: types beyond the mask's domain are excluded (a
        # clamped gather would silently reuse the last type's weight)
        t = edge_type_mask.shape[0]
        ew = ew * jnp.where(g.edge_type < t,
                            edge_type_mask[jnp.clip(g.edge_type, 0, t - 1)],
                            0.0)
    # out-degree normalisation (random-walk style push)
    deg_w = jax.ops.segment_sum(ew, g.src, num_segments=n)
    inv_deg = jnp.where(deg_w > 0, 1.0 / jnp.maximum(deg_w, 1e-12), 0.0)
    nm = None if node_mask is None else node_mask.astype(jnp.float32)

    def hop(frontier, _):
        pushed = frontier * inv_deg                      # (N,)
        msg = pushed[g.src] * ew                         # (E,)
        nxt = jax.ops.segment_sum(msg, g.indices, num_segments=n) * damping
        if nm is not None:
            nxt = nxt * nm
        if top_m:
            kth = jax.lax.top_k(nxt, min(top_m, n))[0][-1]
            nxt = jnp.where(nxt >= kth, nxt, 0.0)
        return nxt, nxt

    seed = seed_scores.astype(jnp.float32)
    if nm is not None:
        seed = seed * nm
    _, per_hop = jax.lax.scan(hop, seed, None, length=n_hops)
    return TraversalResult(per_hop=per_hop, total=per_hop.mean(axis=0))


def seeds_from_topk(n_nodes: int, ids: jax.Array, scores: jax.Array) -> jax.Array:
    """Scatter a (k,) vector-search result into an (N,) seed-mass vector.

    Scores are shifted to be non-negative and normalised so traversal mass is
    comparable across queries (invalid ids < 0 are dropped)."""
    valid = ids >= 0
    smin = jnp.min(jnp.where(valid, scores, jnp.inf))
    w = jnp.where(valid, scores - jnp.where(jnp.isfinite(smin), smin, 0.0) + 1e-6, 0.0)
    w = w / jnp.maximum(jnp.sum(w), 1e-12)
    seed = jnp.zeros((n_nodes,), jnp.float32)
    return seed.at[jnp.clip(ids, 0, n_nodes - 1)].add(jnp.where(valid, w, 0.0))


def multi_hop_batch(g: GraphStore, ids: jax.Array, scores: jax.Array, *,
                    n_hops: int, edge_type_mask=None, node_mask=None,
                    damping: float = 0.85, top_m: int = 0) -> jax.Array:
    """Vmapped traversal for a batch of vector-search results.

    ids/scores: (Q, k) -> (Q, N) graph relevance (mean per-hop mass).
    node_mask: (N,) bool predicate mask shared across the batch.
    edge_type_mask: a (T,) mask or an iterable of edge-type ids (see
    ``as_edge_mask``)."""
    edge_type_mask = as_edge_mask(edge_type_mask)

    def one(i, s):
        seed = seeds_from_topk(g.n_nodes, i, s)
        return frontier_expand(g, seed, n_hops=n_hops,
                               edge_type_mask=edge_type_mask,
                               node_mask=node_mask, damping=damping,
                               top_m=top_m).total
    return jax.vmap(one)(ids, scores)
