"""Public jit'd wrappers for the fused quantized scan.

Off-TPU (this container) the kernel body runs under ``interpret=True`` — the
backend is probed once, lazily on the first kernel call (``_interpret_mode``),
so jit caches never mix modes and app-level JAX setup still runs first; on a
real TPU the same pallas_call compiles to Mosaic. The wrappers
pad N (or M) to the block size and return exact top-k ids/scores.

Exactness: the kernel emits per-chunk (max, argmax) survivors. For the probe
path, ``scan_topk_quantized_batched`` then *rescores every row of the top-k
chunks*: any true top-k row lives in a chunk whose max is ≥ the k-th best
score, and at most k chunks can have such a max, so the k·chunk rescored rows
provably contain the exact (quantized-score) top-k. The rescore touches only
k·chunk rows per query — tiny next to the scan.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ivf_topk.ivf_topk import (scan_topk_pallas,
                                             scan_topk_pallas_batched)
from repro.kernels.ivf_topk.ref import pad_topk, topk_from_chunks


@functools.lru_cache(maxsize=None)
def _interpret_mode() -> bool:
    """Probed once, lazily (first kernel call): Mosaic needs a TPU; every
    other backend interprets. Deferred past import so app-level JAX setup
    (jax.distributed.initialize, platform selection) runs first."""
    return jax.default_backend() != "tpu"


NEG = jnp.float32(-3e38)   # additive mask bias (sign-safe, unlike -inf)


@functools.partial(jax.jit, static_argnames=("k", "chunk", "block_n", "interpret"))
def scan_topk_quantized(queries, data_i8, vmin, scale, valid, *, k: int,
                        chunk: int = 128, block_n: int = 512,
                        interpret: bool | None = None):
    """Top-k over a quantized corpus slab shared by all queries.

    queries (Q, d) fp32; data_i8 (N, d) int8; vmin/scale (N,); valid (N,) bool.
    Returns (scores (Q, k), row_ids (Q, k)) — descending, -inf/-1 padded.
    """
    interp = _interpret_mode() if interpret is None else interpret
    n, d = data_i8.shape
    pad = (-n) % block_n
    if pad:
        data_i8 = jnp.pad(data_i8, ((0, pad), (0, 0)))
        vmin = jnp.pad(vmin, (0, pad))
        scale = jnp.pad(scale, (0, pad), constant_values=1.0)
        valid = jnp.pad(valid, (0, pad))
    # invalid rows get a -3e38 additive bias inside the kernel (sign-safe)
    bias = jnp.where(valid, 0.0, NEG)
    cmax, carg = scan_topk_pallas(queries, data_i8, vmin, scale, bias,
                                  chunk=chunk, block_n=block_n, interpret=interp)
    vals, ids = topk_from_chunks(cmax, carg, min(k, cmax.shape[1]))
    dead = vals <= NEG * 0.5
    vals = jnp.where(dead, -jnp.inf, vals)
    ids = jnp.where(dead, -1, ids)
    return pad_topk(vals, ids, k)


@functools.partial(jax.jit, static_argnames=("k", "chunk", "block_n", "interpret"))
def scan_topk_quantized_batched(queries, data_i8, vmin, scale, valid, *,
                                k: int, chunk: int = 16, block_n: int = 512,
                                interpret: bool | None = None):
    """Exact top-k over per-query quantized slabs (the IVF probe path).

    queries (Q, d) fp32; data_i8 (Q, M, d) int8 — each query's gathered probe
    rows; vmin/scale (Q, M) fp32; valid (Q, M) bool. Returns
    (scores (Q, k), rows (Q, k)) — descending; ``rows`` index each query's own
    slab axis M; -inf/-1 padded. Exact over the quantized scores (see module
    docstring for the top-k-chunks containment argument).
    """
    interp = _interpret_mode() if interpret is None else interpret
    qn, m, d = data_i8.shape
    pad = (-m) % block_n
    if pad:
        data_i8 = jnp.pad(data_i8, ((0, 0), (0, pad), (0, 0)))
        vmin = jnp.pad(vmin, ((0, 0), (0, pad)))
        scale = jnp.pad(scale, ((0, 0), (0, pad)), constant_values=1.0)
        valid = jnp.pad(valid, ((0, 0), (0, pad)))
    bias = jnp.where(valid, 0.0, NEG)
    cmax, _ = scan_topk_pallas_batched(queries, data_i8, vmin, scale, bias,
                                       chunk=chunk, block_n=block_n,
                                       interpret=interp)
    # stage 2: gather every row of the top-k chunks and rescore exactly —
    # ≤ k chunks can hold a true top-k row, so this set contains all of them.
    nchunks = cmax.shape[1]
    kc = min(k, nchunks)
    _, cpos = jax.lax.top_k(cmax, kc)                                 # (Q, kc)
    rows = (cpos[:, :, None] * chunk
            + jnp.arange(chunk, dtype=jnp.int32)[None, None, :])
    rows = rows.reshape(qn, kc * chunk)                               # (Q, R)
    dsel = jnp.take_along_axis(data_i8, rows[:, :, None], axis=1)     # (Q,R,d)
    vsel = jnp.take_along_axis(vmin, rows, axis=1)
    ssel = jnp.take_along_axis(scale, rows, axis=1)
    bsel = jnp.take_along_axis(bias, rows, axis=1)
    q32 = queries.astype(jnp.float32)
    qsum = jnp.sum(q32, axis=-1, keepdims=True)
    dots = jnp.einsum("qd,qrd->qr", q32, dsel.astype(jnp.float32))
    scores = dots * ssel + qsum * (128.0 * ssel + vsel) + bsel
    kk = min(k, scores.shape[1])
    vals, pos = jax.lax.top_k(scores, kk)
    out_rows = jnp.take_along_axis(rows, pos, axis=1)
    dead = vals <= NEG * 0.5
    vals = jnp.where(dead, -jnp.inf, vals)
    out_rows = jnp.where(dead, -1, out_rows)
    return pad_topk(vals, out_rows, k)
