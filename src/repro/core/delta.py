"""MVCC delta store (paper §3.5): insertions/updates/deletions land in a
fixed-capacity buffer; queries hybridise ANNS-on-stable with a scan-on-delta;
asynchronous compaction merges the delta into the IVF partitions without a
full rebuild.

Versioning: every write bumps ``version`` and stamps the rows it writes with
that counter (``row_version``). Visibility rules per read:
  stable row visible  iff  not tombstoned and not superseded
  delta  row visible  iff  not tombstoned and no newer delta version of the
                           same id exists (latest-version-wins)
``superseded`` marks ids whose latest version lives in the delta (an update =
supersede(old) + insert(new)); the latest-version mask covers the
delta-vs-delta case (insert-then-update before compaction), where a stale
row would otherwise outrank the update purely on score. Compaction folds the
latest versions back into the stable index and clears both — either the full
synchronous ``compact`` or, on the adaptive path, fixed-size incremental
drains (``live_slots`` + ``rebuild_keep``, driven by repro/maintenance).
Readers are wait-free: search takes a consistent (stable, delta) snapshot
pair.

Scan path: rows are quantized to int8 at insert time (mirroring the stable
slab layout), so the delta scan runs through the same fused Pallas kernel as
the IVF probe path — int8 HBM traffic, affine dequant folded into the matmul.
The top (k + margin) quantized survivors are then rescored exactly against
the fp32 master rows (a tiny gather), so results stay brute-force-exact
whenever the margin covers the quantization noise — and always when the
delta holds ≤ k + margin rows.

Predicate pushdown: ``_scan_delta``/``search_with_delta`` take an optional
``node_pass`` (max_ids,) bool mask (see core/graph_store.NodeAttributes) that
is folded into the scan validity mask — filtered queries never spend top-k
slots on excluded rows.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import ivf as ivf_mod
from repro.core.graph_store import mask_pass
from repro.core.ivf import IVFIndex
from repro.core.quantization import quantize
from repro.kernels.ivf_topk.ops import scan_topk_quantized
from repro.kernels.ivf_topk.ref import pad_topk

# default extra quantized survivors rescored in fp32 before the final top-k
# (HMGIConfig.delta_rescore_margin overrides per index)
_RESCORE_MARGIN = 16


class DeltaStore(NamedTuple):
    vectors: jax.Array      # (cap, d) fp32 — master rows (compaction, rescore)
    qdata: jax.Array        # (cap, d) int8 — kernel-scan mirror (centered)
    qvmin: jax.Array        # (cap,) fp32 — per-row affine dequant terms
    qscale: jax.Array       # (cap,) fp32
    ids: jax.Array          # (cap,) int32, -1 empty
    row_version: jax.Array  # (cap,) int32 — MVCC audit stamp of the writing
                            # insert (visibility itself reads ``stale``)
    stale: jax.Array        # (cap,) bool — a newer delta version of this id
                            # exists (maintained at write time: O(1) to read)
    count: jax.Array        # () int32
    version: jax.Array      # () int32 — MVCC write counter
    tombstones: jax.Array   # (max_ids,) bool — user deletes
    superseded: jax.Array   # (max_ids,) bool — stale stable rows (updates)


def init(capacity: int, dim: int, max_ids: int) -> DeltaStore:
    return DeltaStore(
        vectors=jnp.zeros((capacity, dim), jnp.float32),
        qdata=jnp.zeros((capacity, dim), jnp.int8),
        qvmin=jnp.zeros((capacity,), jnp.float32),
        qscale=jnp.ones((capacity,), jnp.float32),
        ids=jnp.full((capacity,), -1, jnp.int32),
        row_version=jnp.full((capacity,), -1, jnp.int32),
        stale=jnp.zeros((capacity,), bool),
        count=jnp.zeros((), jnp.int32),
        version=jnp.zeros((), jnp.int32),
        tombstones=jnp.zeros((max_ids,), bool),
        superseded=jnp.zeros((max_ids,), bool),
    )


def _clip_ids(delta: DeltaStore, ids):
    return jnp.clip(ids, 0, delta.tombstones.shape[0] - 1)


@jax.jit
def insert(delta: DeltaStore, vecs: jax.Array, new_ids: jax.Array) -> DeltaStore:
    """Appends a batch (drops silently if full — callers grow/compact first,
    see ``free_slots``/``grow``). Rows are quantized here so reads never touch
    fp32 for the scan, and stamped with the current write version so readers
    can mask all but the latest version of an id. Clears tombstones for
    re-inserted ids."""
    cap = delta.vectors.shape[0]
    n = vecs.shape[0]
    base = delta.count
    slots = jnp.clip(base + jnp.arange(n), 0, cap - 1)
    fits = (base + jnp.arange(n)) < cap
    v32 = vecs.astype(jnp.float32)
    qv = quantize(v32, 8)
    vectors = delta.vectors.at[slots].set(
        jnp.where(fits[:, None], v32, delta.vectors[slots]))
    qdata = delta.qdata.at[slots].set(
        jnp.where(fits[:, None], qv.data, delta.qdata[slots]))
    qvmin = delta.qvmin.at[slots].set(
        jnp.where(fits, qv.vmin[:, 0], delta.qvmin[slots]))
    qscale = delta.qscale.at[slots].set(
        jnp.where(fits, qv.scale[:, 0], delta.qscale[slots]))
    ids = delta.ids.at[slots].set(jnp.where(fits, new_ids.astype(jnp.int32),
                                            delta.ids[slots]))
    rv = delta.row_version.at[slots].set(
        jnp.where(fits, delta.version, delta.row_version[slots]))
    # latest-version-wins, maintained at write time (reads pay nothing):
    # existing rows sharing an id with an *actually written* batch row go
    # stale, as does any batch row with a later same-id row in the batch.
    # Sort-based — O((cap+n)·log n), no (cap, n) or (n, n) intermediates
    # (bulk overflow batches can be large).
    ids_eff = jnp.where(fits, new_ids.astype(jnp.int32), -2)
    sb = jnp.sort(ids_eff)
    pos = jnp.clip(jnp.searchsorted(sb, delta.ids), 0, n - 1)
    hit_old = jnp.logical_and(sb[pos] == delta.ids, delta.ids >= 0)
    stale = jnp.logical_or(delta.stale, hit_old)
    # stable argsort keeps batch order within equal ids: a sorted element
    # followed by its own id is not the last (newest) version
    order = jnp.argsort(ids_eff, stable=True)
    not_last = jnp.concatenate(
        [ids_eff[order][:-1] == ids_eff[order][1:], jnp.zeros((1,), bool)])
    batch_stale = jnp.zeros((n,), bool).at[order].set(not_last)
    stale = stale.at[slots].set(jnp.where(fits, batch_stale, stale[slots]))
    ts = delta.tombstones.at[_clip_ids(delta, new_ids)].set(False)
    return DeltaStore(vectors, qdata, qvmin, qscale, ids, rv, stale,
                      base + jnp.sum(fits.astype(jnp.int32)),
                      delta.version + 1, ts, delta.superseded)


@jax.jit
def supersede(delta: DeltaStore, old_ids: jax.Array) -> DeltaStore:
    """Marks stable rows stale (the update path: supersede + insert)."""
    sp = delta.superseded.at[_clip_ids(delta, old_ids)].set(True)
    return delta._replace(superseded=sp, version=delta.version + 1)


@jax.jit
def delete(delta: DeltaStore, dead_ids: jax.Array) -> DeltaStore:
    ts = delta.tombstones.at[_clip_ids(delta, dead_ids)].set(True)
    return delta._replace(tombstones=ts, version=delta.version + 1)


def free_slots(delta: DeltaStore) -> int:
    return int(delta.vectors.shape[0] - delta.count)


def insert_grow(delta: DeltaStore, vecs: jax.Array,
                new_ids: jax.Array) -> DeltaStore:
    """Host-side insert that never drops rows: grows the store first when
    the batch exceeds the free slots (2x headroom so the result isn't born
    at the compaction threshold). The one spelling of the overflow-routing
    idiom shared by ingest, compaction, repartitioning, and facade inserts."""
    n = int(vecs.shape[0])
    if free_slots(delta) < n:
        delta = grow(delta, int(delta.count) + 2 * n + 1)
    return insert(delta, vecs, new_ids)


def grow(delta: DeltaStore, min_capacity: int) -> DeltaStore:
    """Host-side capacity growth (copy into a larger store). Used when an
    overflow batch (compaction / repartition) exceeds the remaining slots —
    rows must never be dropped silently. Doubles to amortise re-jits."""
    cap = delta.vectors.shape[0]
    if min_capacity <= cap:
        return delta
    new_cap = cap
    while new_cap < min_capacity:
        new_cap *= 2
    pad = new_cap - cap
    return delta._replace(
        vectors=jnp.pad(delta.vectors, ((0, pad), (0, 0))),
        qdata=jnp.pad(delta.qdata, ((0, pad), (0, 0))),
        qvmin=jnp.pad(delta.qvmin, (0, pad)),
        qscale=jnp.pad(delta.qscale, (0, pad), constant_values=1.0),
        ids=jnp.pad(delta.ids, (0, pad), constant_values=-1),
        row_version=jnp.pad(delta.row_version, (0, pad), constant_values=-1),
        stale=jnp.pad(delta.stale, (0, pad)),
    )


def _latest_version_mask(delta: DeltaStore) -> jax.Array:
    """(cap,) bool: True where the row is the newest delta version of its id.

    The delta can hold several live versions of one id (insert-then-update
    before compaction); score-based dedup would happily return the stale
    vector. ``insert`` maintains the staleness bit at write time (slots are
    append-only, so it marks prior same-id rows — and earlier same-id rows
    of its own batch — as superseded), which keeps this read-side mask O(cap)
    regardless of corpus size."""
    return jnp.logical_and(delta.ids >= 0, ~delta.stale)


@functools.partial(jax.jit, static_argnames=("k", "margin"))
def _scan_delta(delta: DeltaStore, queries: jax.Array, *, k: int,
                margin: int = _RESCORE_MARGIN,
                node_pass: Optional[jax.Array] = None):
    """Kernel scan over the quantized delta rows + exact fp32 rescore of the
    top (k + margin) survivors. chunk=1 makes the survivor ordering exact
    over quantized scores (the delta is small; its scan output is tiny).
    Results match brute force exactly whenever the delta holds ≤ k + margin
    live rows, and up to int8 ordering error at the survivor boundary
    otherwise — raise ``margin`` (cfg.delta_rescore_margin) toward
    delta_capacity to trade scan output size for exactness.

    Visibility: tombstones out, stale versions out (see
    ``_latest_version_mask``), and rows failing ``node_pass`` out — predicate
    pushdown happens before the top-k, mirroring the stable probe path."""
    cap = delta.ids.shape[0]
    valid = jnp.logical_and(
        _latest_version_mask(delta),
        ~delta.tombstones[_clip_ids(delta, delta.ids)])
    if node_pass is not None:
        valid = jnp.logical_and(valid, mask_pass(node_pass, delta.ids))
    k_scan = min(cap, k + margin)
    qvals, qrows = scan_topk_quantized(
        queries, delta.qdata, delta.qvmin, delta.qscale, valid, k=k_scan,
        chunk=1, block_n=128)
    rows = jnp.clip(qrows, 0, cap - 1)
    vecs = delta.vectors[rows]                                # (Q, k_scan, d)
    exact = jnp.einsum("qd,qrd->qr", queries.astype(jnp.float32),
                       vecs)
    exact = jnp.where(jnp.logical_and(qrows >= 0, jnp.isfinite(qvals)),
                      exact, -jnp.inf)
    kk = min(k, exact.shape[1])
    vals, pos = jax.lax.top_k(exact, kk)
    di = jnp.take_along_axis(delta.ids[rows], pos, axis=1)
    di = jnp.where(jnp.isfinite(vals), di, -1)
    return pad_topk(vals, di, k)


def _stable_visibility(delta: DeltaStore, node_pass: Optional[jax.Array],
                       mvcc_filter: bool) -> Optional[jax.Array]:
    """The stable scan's pre-top-k validity mask: MVCC visibility
    (tombstones | superseded out) ∧ the optional predicate. The one spelling
    shared by the single-device and sharded paths — their results must stay
    bit-identical, so their visibility semantics must not be able to drift.
    mvcc_filter=False is the caller-asserted never-mutated fast path (no
    (N,) mask built when there is no predicate either)."""
    if not mvcc_filter:
        return node_pass
    dead = jnp.logical_or(delta.tombstones, delta.superseded)
    return ~dead if node_pass is None else jnp.logical_and(~dead, node_pass)


def search_with_delta(index: IVFIndex, delta: DeltaStore, queries: jax.Array, *,
                      n_probe: int, k: int,
                      rescore_margin: int = _RESCORE_MARGIN,
                      probes: Optional[jax.Array] = None,
                      node_pass: Optional[jax.Array] = None,
                      impl: str = "auto",
                      mvcc_filter: bool = True) -> Tuple[jax.Array, jax.Array]:
    """Stable-ANNS ∪ delta-kernel-scan, visibility-filtered, dedup-merged.

    probes: optional precomputed partition assignment (see ivf.search).
    node_pass: optional predicate mask pushed into both scans.

    MVCC visibility (tombstones, superseded ids) is pushed into the stable
    scan's validity mask exactly like the predicate — *pre* top-k. Masking
    after the scan would let dead rows waste top-k slots (an update whose
    old vector scores well would push a live k-th result out), so a scan at
    full probe would no longer match brute force over the visible corpus.

    mvcc_filter=False is the caller-asserted fast path for indexes that
    have never seen a delete or update (the facade tracks this per
    modality): it skips building the (N,) visibility mask and keeps the
    unfiltered scan off the masked-gather lane."""
    visible = _stable_visibility(delta, node_pass, mvcc_filter)
    sv, si = ivf_mod.search(index, queries, n_probe=n_probe, k=k,
                            probes=probes, node_pass=visible, impl=impl)
    dv, di = _scan_delta(delta, queries, k=k, margin=rescore_margin,
                         node_pass=node_pass)
    # delta may hold multiple versions of an id (insert-after-insert): stale
    # versions are masked in _scan_delta; dedup covers stable-vs-delta overlap
    mv, mi = ivf_mod.dedup_merge_topk(sv, si, dv, di, k)
    # -inf slots are "no result": don't leak a masked (e.g. tombstoned) id
    return mv, jnp.where(jnp.isfinite(mv), mi, -1)


def search_with_delta_sharded(sharded: IVFIndex, delta: DeltaStore,
                              queries: jax.Array, mesh, *, n_probe: int, k: int,
                              rescore_margin: int = _RESCORE_MARGIN,
                              probes: Optional[jax.Array] = None,
                              node_pass: Optional[jax.Array] = None,
                              impl: str = "auto",
                              mvcc_filter: bool = True) -> Tuple[jax.Array, jax.Array]:
    """``search_with_delta`` over a row-sharded stable store (the sharded
    execution path): per-shard masked probes + cross-shard merge via
    ``ivf.search_sharded``, one replicated delta scan, dedup-merge.

    ``sharded`` is an ``ivf.shard_index`` layout (leading shard dim per
    leaf). The MVCC visibility mask and the predicate mask are built exactly
    as in the single-device path and broadcast (replicated) into every
    shard's scan — pre-top-k, so per-shard top-k lists only ever hold
    visible, qualifying rows. The delta is replicated state: scanning it once
    outside the shard_map and merging host-side is both cheaper than S
    redundant scans and keeps the two paths' results identical."""
    visible = _stable_visibility(delta, node_pass, mvcc_filter)
    with obs.span("sharded.scan") as sp:
        sv, si = sp.fence(ivf_mod.search_sharded(
            sharded, queries, mesh, n_probe=n_probe, k=k, probes=probes,
            node_pass=visible, impl=impl))
    # everything after the per-shard scans is the sharded path's extra cost
    # over single-device execution — surfaced as the "sharded.merge" span
    with obs.span("sharded.merge") as sp:
        # the distributed section ends at the cross-shard merge: the (Q, k)
        # candidate state is tiny, and every downstream stage (delta merge,
        # traversal, fusion) is a single-device computation — pulling the
        # replicated result onto the default device here keeps those stages
        # compiling exactly as in the single-device path
        sv, si = jax.device_put((sv, si), jax.devices()[0])
        dv, di = _scan_delta(delta, queries, k=k, margin=rescore_margin,
                             node_pass=node_pass)
        mv, mi = ivf_mod.dedup_merge_topk(sv, si, dv, di, k)
        return sp.fence((mv, jnp.where(jnp.isfinite(mv), mi, -1)))


def should_compact(delta: DeltaStore, threshold: float = 0.5) -> bool:
    """True when the delta holds ≥ threshold·capacity rows (counting stale
    and drained slots: ``count`` is the append watermark, the quantity that
    actually exhausts capacity)."""
    return int(delta.count) >= int(threshold * delta.vectors.shape[0])


# ---------------------------------------------------------------------------
# incremental drain (bounded-work compaction steps; maintenance/executor.py)
# ---------------------------------------------------------------------------

def live_slots(delta: DeltaStore):
    """Host: slot indices (ascending — oldest write first) of rows visible
    to the delta scan: latest version per id, not tombstoned. The incremental
    compactor drains a bounded prefix of this list per step."""
    ids = np.asarray(delta.ids)
    tomb = np.asarray(delta.tombstones)
    ok = np.asarray(_latest_version_mask(delta)) \
        & ~tomb[np.clip(ids, 0, tomb.shape[0] - 1)]
    return np.where(ok)[0]


def rebuild_keep(delta: DeltaStore, keep_slots, clear_superseded_ids=None
                 ) -> DeltaStore:
    """Fresh store holding only ``keep_slots``'s rows — the drain step's
    tail: drained / stale / tombstone-shadowed slots vanish and the kept
    rows re-pack from slot 0 as one fixed-(cap,)-shape gather (their stored
    bytes move untouched — and the shape never depends on how many rows
    survive, so repeated drain steps hit the same compiled executables).
    Tombstones carry over; the version stays monotone.
    ``clear_superseded_ids`` marks ids whose latest version just moved into
    the stable store — their stable row is live again, so the superseded
    bit must drop with the delta row."""
    sp = delta.superseded
    if clear_superseded_ids is not None and len(clear_superseded_ids):
        sp = sp.at[_clip_ids(delta, jnp.asarray(
            np.asarray(clear_superseded_ids, np.int32)))].set(False)
    cap = delta.vectors.shape[0]
    keep_slots = np.asarray(keep_slots, np.int64)
    n = int(keep_slots.size)
    # (cap,) gather map: kept rows to the front, slot 0 as a harmless
    # source for the (masked-out) tail
    src = np.zeros(cap, np.int64)
    src[:n] = keep_slots
    gs = jnp.asarray(src)
    valid = jnp.arange(cap) < n
    return DeltaStore(
        vectors=jnp.where(valid[:, None], delta.vectors[gs], 0.0),
        qdata=jnp.where(valid[:, None], delta.qdata[gs], 0),
        qvmin=jnp.where(valid, delta.qvmin[gs], 0.0),
        qscale=jnp.where(valid, delta.qscale[gs], 1.0),
        ids=jnp.where(valid, delta.ids[gs], -1),
        row_version=jnp.where(valid, delta.row_version[gs], -1),
        stale=jnp.zeros((cap,), bool),      # kept rows are one-per-id live
        count=jnp.asarray(n, jnp.int32),
        version=delta.version + 1,
        tombstones=delta.tombstones,
        superseded=sp,
    )


def compact(key, index: IVFIndex, delta: DeltaStore,
            all_vectors: jax.Array, all_ids: jax.Array) -> Tuple[IVFIndex, DeltaStore]:
    """Full synchronous compaction: merge live delta rows into the stable
    index by re-running the (cheap) assignment against *existing* centroids —
    no K-means refit (paper: "incremental merges into snapshots"). This is
    the one-shot fallback; the bounded-work path drains chunks instead
    (``live_slots``/``rebuild_keep`` + repro/maintenance, docs/DESIGN.md
    §3.4). Centroid drift is handled there too (recluster/split actions).

    all_vectors/all_ids: the full live corpus with one latest row per id
    (facade-provided); returns (new_index, fresh_delta). Overflow rows that
    don't fit their partition are re-queued in the fresh delta — growing it
    when they exceed its capacity, never truncating (rows must stay
    searchable until the next repartition widens the slabs)."""
    live = ~delta.tombstones[_clip_ids(delta, all_ids)]
    vecs = jnp.where(live[:, None], all_vectors, 0.0)
    ids = jnp.where(live, all_ids, -1)
    new_index, overflow = ivf_mod.build(key, vecs, ids,
                                        n_partitions=index.n_partitions,
                                        capacity=index.capacity, bits=index.bits,
                                        centroids=index.centroids)
    # rows that didn't fit their partition stay queryable via the fresh delta
    over = jnp.logical_and(overflow, live)
    n_over = int(jnp.sum(over))
    fresh = init(delta.vectors.shape[0], delta.vectors.shape[1],
                 delta.tombstones.shape[0])
    fresh = fresh._replace(version=delta.version + 1, tombstones=delta.tombstones)
    if n_over:
        sel = jnp.where(over)[0]
        fresh = insert_grow(fresh, all_vectors[sel], all_ids[sel])
    return new_index, fresh
